"""Fused-round differential tests: the fused path (round_fuse stages 1-3
in one operation) must be bit-identical to the staged round through whole
engine histories — single and sharded, per-round and superstep — must
never retrace under QoS/admission churn, and must fall back to the staged
path exactly when a non-fusable (transcendental) program is installed.
Also pins the drop-accounting fixes that rode along: the DLQ tenant
sentinel (-1, not tenant 0) and its round-trip through redeliver()."""
from typing import Optional

import numpy as np
import pytest

try:        # the hypothesis differential skips without it; the fixed-seed
    from hypothesis import given, settings, strategies as st  # ones still run
except ImportError:
    def given(*a, **k):
        return lambda f: pytest.mark.skip(
            reason="hypothesis not installed")(f)

    def settings(*a, **k):
        return lambda f: f

    class st:                                # placeholder strategy namespace
        @staticmethod
        def composite(f):
            return lambda *a, **k: None

        @staticmethod
        def data():
            return None

import jax.numpy as jnp

from repro.core import EngineConfig, Registry, create_engine


# --------------------------------------------------------------------------
# engine-history differential harness
# --------------------------------------------------------------------------

def _build(fused: bool, n_shards: int = 1, superstep: int = 1, seed: int = 0,
           dlq: int = 16):
    cfg = EngineConfig(n_streams=64, n_tenants=4, channels=3, max_in=4,
                       max_out=4, batch=8, queue=128, prog_len=16,
                       n_consts=8, n_temps=8, sink_buffer=32,
                       dlq_slots=dlq, retention_slots=2,
                       n_shards=n_shards, superstep=superstep,
                       fused_round=fused).validate()
    reg = Registry(cfg)
    t0 = reg.create_tenant("a")
    t1 = reg.create_tenant("b")
    srcs = [reg.create_stream(t0, f"s{i}", ["x", "y", "z"])
            for i in range(6)]
    c0 = reg.create_composite(t0, "c0", ["x", "y", "z"], srcs[:3],
                              {"x": "s0.x + s1.y", "y": "out.y + 1",
                               "z": "min(s2.z, 4.0)"},
                              post_filter="out.x < 100")
    reg.create_composite(t1, "c1", ["x", "y", "z"], [srcs[3], c0],
                         {"x": "c0.x * 2", "y": "s3.y - c0.z",
                          "z": "abs(s3.z)"})
    eng = create_engine(reg)
    return eng, (t0, t1), srcs, c0


def _run(eng, srcs, rounds: int, seed: int, superstep: int = 1):
    rng = np.random.default_rng(seed)
    sinks = []
    for r in range(rounds):
        for s in srcs:
            if rng.random() < 0.8:
                eng.post(s, rng.standard_normal(3).tolist(),
                         r * 10 + int(rng.integers(0, 9)))
        if superstep > 1:
            for sp in eng.drain_spools(superstep, max_rounds=superstep):
                sinks.extend(eng.spool_sinks(sp))
        else:
            sinks.append(eng.round())
    return sinks


def _arrs(eng, sinks):
    from repro.core.engine import EngineState
    out = {}
    for f in EngineState._fields:
        if f == "stats":
            for k, v in eng.state.stats.items():
                out[f"stats/{k}"] = np.asarray(v)
        else:
            out[f"state/{f}"] = np.asarray(getattr(eng.state, f))
    for i, s in enumerate(sinks):
        out[f"sink{i}/sid"] = np.asarray(s.sid)
        out[f"sink{i}/vals"] = np.asarray(s.vals)
        out[f"sink{i}/valid"] = np.asarray(s.valid)
    return out


def _assert_bitwise(a, b):
    assert a.keys() == b.keys()
    for k in a:
        x, y = a[k], b[k]
        assert x.shape == y.shape, k
        np.testing.assert_array_equal(
            x.view(np.int32) if x.dtype == np.float32 else x,
            y.view(np.int32) if y.dtype == np.float32 else y,
            err_msg=k)


@pytest.mark.parametrize("n_shards,superstep",
                         [(1, 1), (1, 3), (2, 1), (2, 3)])
def test_fused_bit_identical_to_staged(n_shards, superstep):
    """Whole-history differential: every state leaf, stat and sink of the
    fused engine matches the staged engine bitwise (float32 compared in
    bit space, so -0.0 and NaN payloads count too)."""
    e0, _, srcs0, _ = _build(False, n_shards, superstep)
    e1, _, srcs1, _ = _build(True, n_shards, superstep)
    assert e0._path == "staged" and e1._path == "fused"
    s0 = _run(e0, srcs0, 12, seed=7, superstep=superstep)
    s1 = _run(e1, srcs1, 12, seed=7, superstep=superstep)
    _assert_bitwise(_arrs(e0, s0), _arrs(e1, s1))


def test_fused_zero_retrace_under_churn():
    """The retrace contract holds on the fused path: weight/quota edits,
    admission, revocation and program swaps (to fusable programs) are all
    table edits — the compiled step's trace-cache stays at one entry."""
    eng, (t0, t1), srcs, c0 = _build(True)
    assert eng._path == "fused"
    _run(eng, srcs, 2, seed=1)
    cache0 = eng._step._cache_size()
    assert cache0 == 1

    eng.set_weight(t0, 5)
    eng.set_quota(t1, 100, burst=200)
    _run(eng, srcs, 1, seed=2)
    s_new = eng.admit_stream(t0, "late", ["x", "y", "z"], priority=1)
    c_new = eng.admit_composite(t1, "lc", ["x", "y", "z"], [s_new, srcs[0]],
                                {"x": "late.x - s0.y", "y": "out.y * 0.5",
                                 "z": "max(late.z, 0.0)"})
    _run(eng, srcs + [s_new], 2, seed=3)
    eng.swap_program(c_new, {"x": "late.x", "y": "0.0", "z": "s0.z + 1"})
    eng.revoke_stream(c_new)
    eng.set_weight(t0, 0)
    eng.set_quota(t1, 0)
    _run(eng, srcs + [s_new], 2, seed=4)

    assert eng._path == "fused"
    assert eng._step._cache_size() == cache0 == 1


def test_fallback_flips_on_transcendental_swap():
    """Installing a transcendental program flips the engine to the staged
    path (still bit-identical to an always-staged engine); swapping back
    to fusable code returns to the fused path."""
    e0, _, srcs0, c0_0 = _build(False)
    e1, _, srcs1, c0_1 = _build(True)
    s0 = _run(e0, srcs0, 3, seed=11)
    s1 = _run(e1, srcs1, 3, seed=11)

    hot = {"x": "exp(s0.x)", "y": "out.y + 1", "z": "min(s2.z, 4.0)"}
    e0.swap_program(c0_0, hot, post_filter="out.x < 100")
    e1.swap_program(c0_1, hot, post_filter="out.x < 100")
    assert e1._path == "staged"          # exp is not fusable
    s0 += _run(e0, srcs0, 3, seed=12)
    s1 += _run(e1, srcs1, 3, seed=12)

    cool = {"x": "s0.x + s1.y", "y": "out.y + 1", "z": "min(s2.z, 4.0)"}
    e0.swap_program(c0_0, cool, post_filter="out.x < 100")
    e1.swap_program(c0_1, cool, post_filter="out.x < 100")
    assert e1._path == "fused"
    s0 += _run(e0, srcs0, 3, seed=13)
    s1 += _run(e1, srcs1, 3, seed=13)

    _assert_bitwise(_arrs(e0, s0), _arrs(e1, s1))
    assert e0._path == "staged"          # fused_round=False never fuses


def test_revoked_rows_stay_fusable():
    """Revocation clears the row's program to NOPs, so revoking the only
    non-fusable stream returns the engine to the fused path."""
    eng, (t0, t1), srcs, c0 = _build(True)
    hot = eng.admit_composite(t1, "hot", ["x", "y", "z"], [srcs[4]],
                              {"x": "log(s4.x)", "y": "s4.y", "z": "s4.z"})
    assert eng._path == "staged"
    eng.revoke_stream(hot)
    assert eng._path == "fused"
    _run(eng, srcs, 2, seed=5)


# --------------------------------------------------------------------------
# DLQ tenant sentinel (drop-accounting bugfix)
# --------------------------------------------------------------------------

def test_dlq_unknown_tenant_records_sentinel():
    """``dlq_append(tenant=None)`` must record -1 (owner unknown), not
    charge tenant 0, and the sentinel must round-trip through
    ``dead_letters()`` and ``redeliver()`` without corrupting any
    per-tenant counter (-1 would otherwise wrap to the *last* tenant in
    ``.at[]`` updates)."""
    from repro.core.engine import DLQ_OVERFLOW, dlq_append

    eng, (t0, t1), srcs, c0 = _build(True)
    sid = jnp.full((2,), srcs[0].sid, jnp.int32)
    vals = jnp.asarray([[1.0, 2.0, 3.0], [4.0, 5.0, 6.0]], jnp.float32)
    ts = jnp.asarray([3, 4], jnp.int32)
    eng.state = dlq_append(eng.state, sid, vals, ts, None, DLQ_OVERFLOW,
                           jnp.asarray([True, True]))

    letters = eng.dead_letters(clear=True)
    assert [lt.tenant for lt in letters] == [-1, -1]
    assert [lt.reason for lt in letters] == ["overflow", "overflow"]

    charged_before = np.asarray(eng.state.tenant_dropped_overflow).copy()
    queued_before = int(np.asarray(eng.state.q_valid).sum())
    assert eng.redeliver(letters) == 2
    # sentinel letters re-enqueue (requeue path, not tenant-0 ingest) ...
    assert int(np.asarray(eng.state.q_valid).sum()) == queued_before + 2
    eng.round()
    # ... and no per-tenant overflow counter moved: -1 is chargeable to
    # nobody, and must not wrap onto the last tenant
    np.testing.assert_array_equal(
        np.asarray(eng.state.tenant_dropped_overflow), charged_before)


def test_enqueue_overflow_without_tenant_charges_nobody():
    """An overflow drop with the -1 sentinel must not wrap onto the last
    tenant's drop counter (the ``.at[]`` negative-index wrap bug)."""
    from repro.core.engine import _enqueue, init_state

    cfg = EngineConfig(n_streams=8, n_tenants=3, channels=1, max_in=2,
                       max_out=2, batch=2, queue=2, prog_len=4,
                       n_consts=2, n_temps=2, sink_buffer=4,
                       dlq_slots=4).validate()
    state = init_state(cfg)
    sid = jnp.asarray([1, 2, 3, 4], jnp.int32)
    vals = jnp.ones((4, 1), jnp.float32)
    ts = jnp.asarray([1, 1, 1, 1], jnp.int32)
    mask = jnp.ones((4,), bool)
    state, dropped = _enqueue(state, sid, vals, ts, mask,
                              tenant=jnp.full((4,), -1, jnp.int32))
    assert int(dropped) == 2                            # queue holds 2 of 4
    # per-tenant shed counters untouched: the sentinel lands in the
    # overflow pad row (index T), not tenant T-1 via negative-index wrap
    np.testing.assert_array_equal(np.asarray(state.tenant_dropped_overflow),
                                  np.zeros(cfg.n_tenants, np.int32))
    # drop-class accounting reaches the DLQ with the sentinel preserved
    np.testing.assert_array_equal(np.asarray(state.dlq_tenant[:2]),
                                  np.full(2, -1, np.int32))


# --------------------------------------------------------------------------
# hypothesis ref-level differential (skips without hypothesis)
# --------------------------------------------------------------------------

def _ref_case(prio, seq, valid, tenant, weight, sid, ts, payload_bits,
              revoked, retired):
    """Assemble one differential case from drawn primitives."""
    Q, N, B, F, M, C, L = 24, 12, 4, 3, 3, 2, 6
    rng = np.random.default_rng(payload_bits)
    prio = np.asarray(prio, np.int32)
    vals = rng.standard_normal((Q, C)).astype(np.float32)
    vals.ravel()[rng.integers(0, Q * C, 2)] = [np.inf, -0.0]
    q_valid = np.asarray(valid, bool)
    q_valid[retired % Q] = False                         # retired slot
    out_table = rng.integers(-1, N, (N, F)).astype(np.int32)
    in_table = rng.integers(-1, N, (N, M)).astype(np.int32)
    active = np.ones(N, bool)
    active[revoked % N] = False                          # revoked row
    return dict(Q=Q, N=N, B=B, F=F, M=M, C=C, L=L, rng=rng,
                prio=prio, seq=np.asarray(seq, np.int32), q_valid=q_valid,
                tenant=np.asarray(tenant, np.int32),
                weight=np.asarray(weight, np.int32),
                sid=np.asarray(sid, np.int32), vals=vals,
                ts=np.asarray(ts, np.int32), out_table=out_table,
                in_table=in_table, active=active)


def _check_ref_vs_staged(c):
    from repro.core import program as pvm
    from repro.core.engine import fanout_reference, process_work_items
    from repro.kernels.round_fuse import ref as rfr

    Q, N, B, F, C, L = c["Q"], c["N"], c["B"], c["F"], c["C"], c["L"]
    rng = c["rng"]
    cfg = EngineConfig(n_streams=N, n_tenants=4, channels=C,
                       max_in=c["M"], max_out=F, batch=B, queue=Q,
                       prog_len=L, n_consts=4, n_temps=4).validate()
    layout = rfr.RegLayout.from_cfg(cfg)
    ops_pool = np.asarray(sorted(rfr.FUSABLE_OPS), np.int32)
    progs = np.stack([rng.choice(ops_pool, (N, L)),
                      rng.integers(0, layout.n_regs, (N, L)),
                      rng.integers(0, layout.n_regs, (N, L)),
                      rng.integers(0, layout.n_regs, (N, L))],
                     axis=-1).astype(np.int32)
    consts = rng.standard_normal((N, 4)).astype(np.float32)
    is_comp = rng.random(N) < 0.8
    values = rng.standard_normal((N, C)).astype(np.float32)
    timestamps = rng.integers(-5, 30, N).astype(np.int32)
    j = lambda x: jnp.asarray(x)
    w_slot = c["weight"][np.clip(c["tenant"], 0, 3)]

    take, pop, wi = rfr.pop_dispatch_ref(
        j(c["prio"]), j(c["seq"]), j(c["q_valid"]),
        j(np.clip(c["tenant"], 0, 3)), j(w_slot), j(c["sid"]), j(c["vals"]),
        j(c["ts"]), B, j(c["out_table"]), j(c["active"]))
    wi_t, wi_src, wi_vals, wi_ts = wi
    rows = jnp.clip(wi_t, 0, N - 1)
    fused = rfr.apply_programs_ref(
        layout, j(c["in_table"]), j(progs), j(consts), j(is_comp),
        j(c["active"]), rows, rows, wi_src, wi_vals, wi_ts, wi_t >= 0,
        j(values), j(timestamps))

    # staged composition over the identical pop winners
    e_sid, e_vals, e_ts, e_pop, e_act = pop
    targets, _ = fanout_reference(e_sid, e_ts, e_pop & e_act,
                                  j(c["out_table"]), j(timestamps),
                                  with_early=False)
    s_wt = targets.reshape(B * F)
    np.testing.assert_array_equal(np.asarray(wi_t), np.asarray(s_wt))

    from types import SimpleNamespace
    tbl = SimpleNamespace(in_table=j(c["in_table"]), progs=j(progs),
                          consts=j(consts), is_composite=j(is_comp),
                          active=j(c["active"]))

    s_rows = jnp.clip(s_wt, 0, N - 1)
    staged = process_work_items(
        cfg, tbl, s_rows, s_rows, jnp.repeat(e_sid, F),
        jnp.repeat(e_vals, F, axis=0), jnp.repeat(e_ts, F), s_wt >= 0,
        j(values), j(timestamps))

    new_vals, ts_out, live, keep, keep_ts, passf, badf = fused
    s_new_vals, s_ts_out, s_live, s_keep, counts, s_badf = staged
    np.testing.assert_array_equal(np.asarray(new_vals).view(np.int32),
                                  np.asarray(s_new_vals).view(np.int32))
    np.testing.assert_array_equal(np.asarray(ts_out), np.asarray(s_ts_out))
    np.testing.assert_array_equal(np.asarray(live), np.asarray(s_live))
    np.testing.assert_array_equal(np.asarray(keep), np.asarray(s_keep))
    # the poison detector itself is part of the differential contract
    np.testing.assert_array_equal(np.asarray(badf), np.asarray(s_badf))
    assert int(counts["processed"]) == int(live.sum())
    assert int(counts["discarded_stale"]) == int((live & ~keep_ts).sum())
    assert int(counts["filtered"]) == int((live & keep_ts & ~passf).sum())
    assert int(counts["nonfinite"]) == int((badf & (wi_t >= 0)).sum())


@settings(max_examples=25, deadline=None)
@given(st.data())
def test_ref_differential_hypothesis(data):
    Q = 24
    d = lambda lo, hi, n: data.draw(st.lists(st.integers(lo, hi),
                                             min_size=n, max_size=n))
    c = _ref_case(
        prio=d(0, 3, Q), seq=d(-5, 50, Q),
        valid=[v == 1 for v in d(0, 1, Q)],
        tenant=d(0, 3, Q), weight=d(0, 9, 4),
        sid=d(0, 15, Q),                    # some out-of-range (N=12)
        ts=d(-20, 40, Q),
        payload_bits=data.draw(st.integers(0, 2**31 - 1)),
        revoked=data.draw(st.integers(0, 11)),
        retired=data.draw(st.integers(0, 23)))
    _check_ref_vs_staged(c)


@pytest.mark.parametrize("seed", range(6))
def test_ref_differential_fixed(seed):
    """Deterministic differential cases — the same check hypothesis runs,
    alive even without hypothesis installed."""
    rng = np.random.default_rng(100 + seed)
    Q = 24
    c = _ref_case(
        prio=rng.integers(0, 4, Q), seq=rng.integers(-5, 50, Q),
        valid=rng.random(Q) < 0.7, tenant=rng.integers(0, 4, Q),
        weight=rng.integers(0, 10, 4), sid=rng.integers(0, 16, Q),
        ts=rng.integers(-20, 40, Q),
        payload_bits=int(rng.integers(0, 2**31 - 1)),
        revoked=int(rng.integers(0, 12)), retired=int(rng.integers(0, 24)))
    _check_ref_vs_staged(c)
