"""Model-plane tests: per-arch smoke (reduced configs, forward + train
step, shape/NaN assertions) and the strong cache-consistency property —
prefill + one decode step reproduces the full-sequence forward logits."""
import numpy as np
import pytest

import jax
import jax.numpy as jnp

from repro import configs, optim
from repro.models import model as M

pytestmark = pytest.mark.slow   # model plane — run with -m "slow or not slow"

ARCHS = configs.list_archs()
B, L = 2, 32


def _batch(cfg, rng, b=B, l=L):
    ks = jax.random.split(rng, 3)
    if cfg.embed_inputs:
        return {"embeds": jax.random.normal(ks[0], (b, l, cfg.d_model),
                                            cfg.cdtype),
                "labels": jax.random.randint(ks[1], (b, l), 0, cfg.vocab)}
    if cfg.n_codebooks > 1:
        return {"tokens": jax.random.randint(ks[0], (b, l, cfg.n_codebooks),
                                             0, cfg.vocab),
                "labels": jax.random.randint(ks[1], (b, l, cfg.n_codebooks),
                                             0, cfg.vocab)}
    return {"tokens": jax.random.randint(ks[0], (b, l), 0, cfg.vocab),
            "labels": jax.random.randint(ks[1], (b, l), 0, cfg.vocab)}


@pytest.fixture(scope="module")
def rng():
    return jax.random.PRNGKey(0)


@pytest.mark.parametrize("arch", ARCHS)
def test_smoke_forward_and_train(arch, rng):
    cfg = configs.get_smoke(arch)
    params = M.init_params(M.param_specs(cfg), rng)
    batch = _batch(cfg, rng)
    logits, _, aux = M.forward(cfg, params, tokens=batch.get("tokens"),
                               embeds=batch.get("embeds"))
    v = cfg.vocab
    expect = (B, L, cfg.n_codebooks, v) if cfg.n_codebooks > 1 else (B, L, v)
    assert logits.shape == expect
    assert np.isfinite(np.asarray(logits, np.float32)).all()
    step = jax.jit(M.make_train_step(cfg))
    p2, opt2, m = step(params, optim.adamw_init(params), batch,
                       jnp.zeros((), jnp.int32))
    assert np.isfinite(float(m["loss"]))
    assert float(m["grad_norm"]) > 0


@pytest.mark.parametrize("arch", ARCHS)
def test_prefill_decode_matches_forward(arch, rng):
    """Teacher-forcing consistency: full forward logits at the last
    position == prefill(L-1) + decode(token L-1).  Exercises every cache
    type (KV global/local ring, mamba conv+ssm, mLSTM C/n/m, sLSTM)."""
    cfg = configs.get_smoke(arch)
    params = M.init_params(M.param_specs(cfg), rng)
    batch = _batch(cfg, rng)
    full, _, _ = M.forward(cfg, params, tokens=batch.get("tokens"),
                           embeds=batch.get("embeds"))
    want = np.asarray(full[:, -1], np.float32)

    def cut(d, sl):
        return {k: v[:, sl] for k, v in d.items() if k != "labels"}

    prefill = jax.jit(M.make_prefill_step(cfg, pad_to=L))
    _, caches = prefill(params, cut(batch, slice(0, L - 1)))
    decode = jax.jit(M.make_decode_step(cfg))
    lg, _ = decode(params, caches, cut(batch, slice(L - 1, L)),
                   jnp.full((B,), L - 1, jnp.int32))
    got = np.asarray(lg[:, 0], np.float32)
    np.testing.assert_allclose(got, want, rtol=2e-3, atol=2e-3)


@pytest.mark.parametrize("arch", ARCHS)
def test_full_config_validates_and_counts(arch):
    cfg = configs.get_config(arch)
    n = M.count_params(cfg)
    na = M.count_params(cfg, active_only=True)
    assert n > 0 and 0 < na <= n
    if cfg.n_experts:
        assert na < n                      # MoE: active strictly smaller
    assert len(cfg.layer_specs) == cfg.n_layers


def test_decode_beyond_window_uses_ring(rng):
    """Sliding-window ring: decode far past the window stays finite and
    consistent with a fresh forward over the visible window."""
    cfg = configs.get_smoke("gemma3-1b")
    params = M.init_params(M.param_specs(cfg), rng)
    toks = jax.random.randint(rng, (1, cfg.window * 3), 0, cfg.vocab)
    prefill = jax.jit(M.make_prefill_step(cfg, pad_to=cfg.window * 3))
    decode = jax.jit(M.make_decode_step(cfg))
    Lp = cfg.window * 3 - 1
    _, caches = prefill(params, {"tokens": toks[:, :Lp]})
    lg, _ = decode(params, caches, {"tokens": toks[:, Lp:Lp + 1]},
                   jnp.full((1,), Lp, jnp.int32))
    full, _, _ = M.forward(cfg, params, tokens=toks)
    np.testing.assert_allclose(np.asarray(lg[:, 0], np.float32),
                               np.asarray(full[:, -1], np.float32),
                               rtol=2e-3, atol=2e-3)


def test_grad_accum_equivalence(rng):
    """grad_accum=2 gives the same update as accum=1 (up to fp error)."""
    import dataclasses
    cfg = configs.get_smoke("minitron-8b")
    params = M.init_params(M.param_specs(cfg), rng)
    batch = _batch(cfg, rng, b=4)
    outs = []
    for accum in (1, 2):
        c = dataclasses.replace(cfg, grad_accum=accum)
        step = jax.jit(M.make_train_step(c))
        p2, _, m = step(jax.tree.map(jnp.copy, params),
                        optim.adamw_init(params), batch,
                        jnp.zeros((), jnp.int32))
        outs.append((p2, m))
    l1, l2 = float(outs[0][1]["loss"]), float(outs[1][1]["loss"])
    np.testing.assert_allclose(l1, l2, rtol=1e-5)
    a = jax.tree.leaves(outs[0][0])
    b = jax.tree.leaves(outs[1][0])
    for x, y in zip(a, b):
        np.testing.assert_allclose(np.asarray(x), np.asarray(y),
                                   rtol=5e-4, atol=5e-5)


def test_ssm_seq_mode_matches_assoc(rng):
    """ssm_mode='seq' (chunk-recompute custom VJP) == 'assoc' for values
    AND gradients — the §Perf memory optimization is semantics-preserving."""
    import dataclasses
    import numpy as np
    from repro.models.ssm import ssm_scan, _seq_scan

    r = np.random.default_rng(0)
    B, L, Di, S, ck = 2, 32, 16, 8, 8
    a = jnp.asarray(np.exp(-np.abs(r.standard_normal((B, L, Di, S)))), jnp.float32)
    bx = jnp.asarray(r.standard_normal((B, L, Di, S)), jnp.float32)
    c = jnp.asarray(r.standard_normal((B, L, S)), jnp.float32)
    h0 = jnp.asarray(r.standard_normal((B, Di, S)), jnp.float32)
    gy = jnp.asarray(r.standard_normal((B, L, Di)), jnp.float32)

    la = lambda *t: jnp.sum(ssm_scan(*t, ck)[0] * gy)
    ls = lambda *t: jnp.sum(_seq_scan(*t, ck)[0] * gy)
    np.testing.assert_allclose(np.asarray(la(a, bx, c, h0)),
                               np.asarray(ls(a, bx, c, h0)), rtol=1e-5)
    g1 = jax.grad(la, argnums=(0, 1, 2, 3))(a, bx, c, h0)
    g2 = jax.grad(ls, argnums=(0, 1, 2, 3))(a, bx, c, h0)
    for x, y in zip(g1, g2):
        np.testing.assert_allclose(np.asarray(x), np.asarray(y),
                                   rtol=2e-4, atol=2e-4)

    # end-to-end: jamba smoke trains identically under both modes
    cfg_a = configs.get_smoke("jamba-v0.1-52b")
    cfg_s = dataclasses.replace(cfg_a, ssm_mode="seq")
    params = M.init_params(M.param_specs(cfg_a), rng)
    batch = _batch(cfg_a, rng)
    for cfg2 in (cfg_a, cfg_s):
        loss = M.make_loss_fn(cfg2)(params, batch)
        if cfg2 is cfg_a:
            base = float(loss)
        else:
            np.testing.assert_allclose(float(loss), base, rtol=1e-5)
