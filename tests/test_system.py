"""End-to-end behaviour of the paper's system: the Listing-1 example,
execution-tree deduplication (Fig. 2a/3), cycle discards (Fig. 2b), live
user-code injection (§IV-F) and dynamic rewiring."""
import numpy as np
import pytest

from repro.core import EngineConfig, Registry, StreamEngine


@pytest.fixture()
def small_cfg():
    return EngineConfig(n_streams=32, batch=8, queue=128, max_in=4, max_out=4)


def test_listing1_f_to_c_pipeline(small_cfg):
    reg = Registry(small_cfg)
    alice = reg.create_tenant("alice")
    bob = reg.create_tenant("bob")
    wo = reg.create_stream(alice, "thermo", ["f"])
    cel = reg.create_composite(
        bob, "celsius", ["c"], [wo],
        transform={"c": "(thermo.f - 32) * 5 / 9"},
        post_filter="out.c < 0")
    eng = StreamEngine(reg)
    eng.post(wo, [14.0], ts=1)    # -10 C -> emitted
    eng.post(wo, [68.0], ts=2)    # +20 C -> filtered
    eng.post(wo, [5.0], ts=3)     # -15 C -> emitted
    eng.drain()
    assert abs(eng.value_of(cel)[0] - (-15.0)) < 1e-4
    assert eng.ts_of(cel) == 3
    c = eng.counters()
    assert c["emitted"] == 2 and c["filtered"] == 1

    # stale SU (paper Listing 2 discard rule — caught at ingest)
    eng.post(wo, [-40.0], ts=2)
    eng.drain()
    assert abs(eng.value_of(cel)[0] - (-15.0)) < 1e-4
    assert eng.counters()["ingest_stale"] >= 1


def test_code_injection_no_recompile(small_cfg):
    reg = Registry(small_cfg)
    t = reg.create_tenant("t")
    wo = reg.create_stream(t, "thermo", ["f"])
    cel = reg.create_composite(t, "c", ["c"],
                               [wo], transform={"c": "(thermo.f - 32) * 5 / 9"})
    eng = StreamEngine(reg)
    compiled_step = eng._step           # the one static program
    eng.post(wo, [212.0], ts=1)
    eng.drain()
    assert abs(eng.value_of(cel)[0] - 100.0) < 1e-3
    eng.inject_code(cel, {"c": "(thermo.f - 32) * 5 / 9 + 273.15"})
    eng.post(wo, [212.0], ts=2)
    eng.drain()
    assert abs(eng.value_of(cel)[0] - 373.15) < 1e-3
    assert eng._step is compiled_step   # tables changed, program did not


def test_diamond_dedup_single_emission(small_cfg):
    """a -> f, g -> x: x must emit once per source update (Fig. 2a)."""
    reg = Registry(small_cfg)
    t = reg.create_tenant("t")
    a = reg.create_stream(t, "a", ["v"])
    f = reg.create_composite(t, "f", ["v"], [a], transform={"v": "a.v + 1"})
    g = reg.create_composite(t, "g", ["v"], [a], transform={"v": "a.v * 2"})
    x = reg.create_composite(t, "x", ["v"], [f, g],
                             transform={"v": "f.v + g.v"})
    eng = StreamEngine(reg)
    eng.post(a, [10.0], ts=1)
    eng.drain()
    c = eng.counters()
    # f, g, x emit exactly once each; the duplicate delivery to x coalesces
    assert c["emitted"] == 3
    assert c["coalesced"] + c["discarded_stale"] >= 1
    assert eng.ts_of(x) == 1


def test_cycle_discard(small_cfg):
    """b -> c -> b cycle (Fig. 2b): deliveries closing the cycle discard."""
    reg = Registry(small_cfg)
    t = reg.create_tenant("t")
    a = reg.create_stream(t, "a", ["v"])
    b = reg.create_composite(t, "b", ["v"], [a], transform={"v": "a.v + 1"})
    c = reg.create_composite(t, "c", ["v"], [b], transform={"v": "b.v + 1"})
    reg.subscribe(b, c)
    eng = StreamEngine(reg)
    eng.post(a, [0.0], ts=5)
    eng.drain()
    cnt = eng.counters()
    assert cnt["emitted"] == 2                 # b and c once each
    assert cnt["discarded_stale"] >= 1         # c -> b closing edge discarded
    assert eng.ts_of(b) == 5 and eng.ts_of(c) == 5


def test_multi_tenant_quota_and_capacity(small_cfg):
    reg = Registry(small_cfg)
    t1 = reg.create_tenant("small", quota_streams=2)
    reg.create_stream(t1, "s1", ["v"])
    reg.create_stream(t1, "s2", ["v"])
    with pytest.raises(ValueError, match="quota"):
        reg.create_stream(t1, "s3", ["v"])
    t2 = reg.create_tenant("big")
    src = reg.create_stream(t2, "src", ["v"])
    with pytest.raises(ValueError, match="in-degree"):
        reg.create_composite(t2, "fat", ["v"],
                             [src] * (small_cfg.max_in + 1),
                             transform={"v": "src.v"})


def test_cross_tenant_subscription_and_attribution(small_cfg):
    """The paper's headline: tenants share data streams between them."""
    reg = Registry(small_cfg)
    alice = reg.create_tenant("alice")
    bob = reg.create_tenant("bob")
    a = reg.create_stream(alice, "a", ["v"])
    b = reg.create_composite(bob, "b", ["v"], [a], transform={"v": "a.v * 2"})
    eng = StreamEngine(reg)
    eng.post(a, [3.0], ts=1)
    eng.drain()
    assert abs(eng.value_of(b)[0] - 6.0) < 1e-6
    emitted = np.asarray(eng.state.tenant_emitted)
    assert emitted[bob.tid] == 1 and emitted[alice.tid] == 0


def test_queue_backlog_drains_without_drops():
    cfg = EngineConfig(n_streams=16, batch=2, queue=4, max_in=2, max_out=2)
    reg = Registry(cfg)
    t = reg.create_tenant("t")
    srcs = [reg.create_stream(t, f"s{i}", ["v"]) for i in range(8)]
    eng = StreamEngine(reg)
    for i, s in enumerate(srcs):
        eng.post(s, [float(i)], ts=i + 1)
    eng.drain(max_rounds=64)
    c = eng.counters()
    assert c["ingested"] == 8
    assert c["dropped_overflow"] == 0


def test_rewire_dynamic_subscription(small_cfg):
    reg = Registry(small_cfg)
    t = reg.create_tenant("t")
    a = reg.create_stream(t, "a", ["v"])
    b = reg.create_stream(t, "b", ["v"])
    x = reg.create_composite(t, "x", ["v"], [a], transform={"v": "a.v"})
    eng = StreamEngine(reg)
    eng.post(a, [1.0], ts=1)
    eng.drain()
    assert abs(eng.value_of(x)[0] - 1.0) < 1e-6
    # dynamically subscribe x to b as well, switch transform to the sum
    reg.subscribe(x, b)
    eng.rewire()
    eng.inject_code(x, {"v": "a.v + b.v"})
    eng.post(b, [5.0], ts=2)
    eng.drain()
    assert abs(eng.value_of(x)[0] - 6.0) < 1e-6


def test_pallas_fanout_inside_engine(small_cfg):
    """Engine with the Pallas stream_dispatch kernel == reference engine."""
    from repro.kernels.stream_dispatch.ops import make_fanout

    def build(fanout=None):
        reg = Registry(small_cfg)
        t = reg.create_tenant("t")
        a = reg.create_stream(t, "a", ["v"])
        f = reg.create_composite(t, "f", ["v"], [a], transform={"v": "a.v + 1"})
        g = reg.create_composite(t, "g", ["v"], [f], transform={"v": "f.v * 2"})
        kw = {"fanout_fn": fanout} if fanout else {}
        return reg, a, g, StreamEngine(reg, **kw)

    _, a1, g1, e1 = build()
    _, a2, g2, e2 = build(make_fanout(interpret=True))
    for eng, a in ((e1, a1), (e2, a2)):
        eng.post(a, [3.0], ts=1)
        eng.post(a, [4.0], ts=2)
        eng.drain()
    assert np.allclose(e1.value_of(g1), e2.value_of(g2))
    assert e1.counters() == e2.counters()
