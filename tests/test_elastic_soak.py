"""Chaos soak for the elastic plane (slow tier).

~200 randomized supersteps of admit / revoke / set_weight / set_quota /
checkpoint / resize / redeliver churn at 1-4 shards, asserting after every
boundary that

  * XLA compiles happen ONLY at resize boundaries (the zero-retrace churn
    contract survives arbitrary interleaving — a warm twin engine
    pre-compiles every shape-keyed global jit first, so the counter
    isolates the soak engine's own programs);
  * SU accounting is conserved: ``queued_in == popped + purged + queue
    occupancy`` exactly, across every migration;
  * and at the end, the final snapshot restores bit-identically at the
    final count AND across counts.
"""
import numpy as np
import pytest

import jax
from jax import monitoring

from repro.core import (EngineConfig, Registry, create_engine,
                        restore_engine)

N_DEV = len(jax.devices())

_COMPILES = []
monitoring.register_event_duration_secs_listener(
    lambda name, dur, **kw: _COMPILES.append(name)
    if name == "/jax/core/compile/backend_compile_duration" else None)

SHARD_LEVELS = (1, 2, 4)
K = 2


def _cfg():
    return EngineConfig(n_streams=12, n_tenants=4, batch=4, queue=32,
                        max_in=4, max_out=4, prog_len=24, n_temps=12,
                        retention_slots=4, dlq_slots=8, superstep=K,
                        checkpoint_every=7)


def _build():
    reg = Registry.with_capacity(_cfg())
    tens = [reg.create_tenant(f"t{i}") for i in range(3)]
    srcs = [reg.create_stream(tens[i], f"s{i}", ["v"]) for i in range(3)]
    comps = []
    for i, a in enumerate(srcs):              # chains keep SUs in flight
        b = reg.create_composite(tens[i], f"b{i}", ["v"], [a],
                                 {"v": "in0.v + 1"})
        comps.append(reg.create_composite(tens[i], f"c{i}", ["v"], [b],
                                          {"v": "in0.v * 2"}))
    return tens, srcs, comps, create_engine(reg)


def _churn(eng, tens, srcs, rng, ts, admitted):
    """One iteration's random churn (everything but resize), via the same
    public API an operator would use."""
    for _ in range(rng.randint(1, 5)):
        eng.post(srcs[rng.randint(len(srcs))], [float(rng.randint(100))], ts)
        ts += 1
    op = rng.randint(6)
    if op == 0:
        s = eng.admit_stream(tens[rng.randint(3)], f"x{ts}", ["v"])
        if s is not None:
            admitted.append(s)
    elif op == 1 and admitted:
        eng.revoke_stream(admitted.pop(rng.randint(len(admitted))))
    elif op == 2:
        eng.set_weight(tens[rng.randint(3)], 1 + rng.randint(4))
    elif op == 3:
        eng.set_quota(tens[rng.randint(3)], 1 + rng.randint(8))
    elif op == 4:
        eng.redeliver()
    # op == 5: pure posting iteration
    return ts


def _assert_conserved(eng, where):
    c = eng.counters()
    occ = int(np.asarray(eng.state.q_valid).sum())
    assert c["queued_in"] == c["popped"] + c["purged"] + occ, \
        f"{where}: queued_in={c['queued_in']} popped={c['popped']} " \
        f"purged={c['purged']} occ={occ}"


@pytest.mark.slow
def test_chaos_soak(tmp_path):
    if N_DEV < max(SHARD_LEVELS):
        pytest.skip(f"needs {max(SHARD_LEVELS)} devices, have {N_DEV}")

    # ---- warm every shape-keyed global jit with a twin -----------------
    # deterministic, not sampled: every churn op runs once at every shard
    # count, so the soak's compile counter sees only the soak engine's own
    # per-resize program
    tens, srcs, _, twin = _build()
    twin.checkpoint_to(str(tmp_path / "warm"))
    ts = 1
    for i in range(3):                        # retention history for replay
        twin.post(srcs[0], [float(i)], ts)
        ts += 1
        twin.drain()
    for n in (1, 2, 4, 2, 1):
        twin.resize(n)
        x = twin.admit_stream(tens[0], f"wx{n}.{ts}", ["v"])
        twin.post(srcs[0], [float(ts)], ts)
        ts += 1
        twin.superstep(K)
        twin.set_weight(tens[0], 2)
        twin.set_quota(tens[1], 3)
        if x is not None:
            twin.post(x, [9.0], ts)           # queued SU -> revoke letter
            ts += 1
            twin.revoke_stream(x)
        late = twin.admit_composite(tens[0], f"wl{n}.{ts}", ["v"],
                                    [srcs[1]], {"v": "in0.v"})
        twin.admit_subscription(late, srcs[0], replay=True)  # warms requeue
        twin.revoke_stream(late)
        twin.redeliver()                      # warms the DLQ drain + clear
        twin.snapshot()
        twin.superstep(K)
    jax.block_until_ready(twin.state.timestamps)
    twin._ckpt.wait()
    twin.checkpoint_to(None)

    # ---- the soak proper ----------------------------------------------
    tens, srcs, _, eng = _build()
    eng.checkpoint_to(str(tmp_path / "soak"))
    rng = np.random.RandomState(42)
    admitted, ts = [], 1
    eng.superstep(K)                          # own closure: first compile
    jax.block_until_ready(eng.state.timestamps)

    resizes = 0
    for step in range(200):
        resized = rng.rand() < 0.08
        before = len(_COMPILES)
        if resized:
            n_now = eng.cfg.n_shards
            choices = [n for n in SHARD_LEVELS if n != n_now]
            eng.resize(choices[rng.randint(len(choices))])
            resizes += 1
        ts = _churn(eng, tens, srcs, rng, ts, admitted)
        eng.superstep(K)
        jax.block_until_ready(eng.state.timestamps)
        compiled = len(_COMPILES) - before
        if resized:
            assert compiled <= 1, \
                f"step {step}: resize cost {compiled} compiles (max 1)"
        else:
            assert compiled == 0, \
                f"step {step}: {compiled} compiles outside a resize boundary"
        _assert_conserved(eng, f"step {step} ({eng.cfg.n_shards} shards)")
    assert resizes >= 5, "soak never exercised resize enough"

    # ---- final state restores bit-identically --------------------------
    eng._ckpt.wait()
    snap = eng.snapshot()
    # same-count restore: every leaf bit-for-bit
    aa, ab = snap[0], restore_engine(snap).snapshot()[0]
    assert sorted(aa) == sorted(ab)
    for k in sorted(aa):
        np.testing.assert_array_equal(aa[k], ab[k], err_msg=k)
    # cross-count roundtrips: resharding renormalizes the queue's slot
    # packing and seq numbering (order-preserving), so queue bookkeeping
    # is compared order-canonically and everything else bit-for-bit
    _QKEYS = {"state/q_sid", "state/q_vals", "state/q_ts", "state/q_seq",
              "state/q_valid", "state/seq"}

    def queue_canon(arrays):
        sid = arrays["state/q_sid"]
        vals = arrays["state/q_vals"]
        ts = arrays["state/q_ts"]
        seq = arrays["state/q_seq"]
        valid = arrays["state/q_valid"]
        if sid.ndim == 1:
            sid, vals, ts = sid[None], vals[None], ts[None]
            seq, valid = seq[None], valid[None]
        return [[(int(sid[s, i]), int(ts[s, i]), tuple(vals[s, i].tolist()))
                 for i in np.argsort(seq[s], kind="stable") if valid[s, i]]
                for s in range(sid.shape[0])]

    # stats/tenant counters live per-shard on the live engine but are
    # consolidated onto shard 0 by resharding: totals must be conserved;
    # quota token buckets are reset by policy on reshard
    _TOTAL_KEYS = {"state/tenant_emitted", "state/tenant_dropped_quota",
                   "state/tenant_dropped_overflow", "state/tenant_queued"}
    _RESET_KEYS = {"state/tokens"}
    for n_via in (1, 2):
        via = restore_engine(snap, n_shards=n_via)
        back = restore_engine(via.snapshot(), n_shards=eng.cfg.n_shards)
        ab = back.snapshot()[0]
        assert sorted(aa) == sorted(ab)
        for k in sorted(aa):
            if k in _QKEYS or k in _RESET_KEYS:
                continue
            if k.startswith("state/stats/"):
                assert aa[k].sum() == ab[k].sum(), f"via {n_via}: {k}"
            elif k in _TOTAL_KEYS:
                np.testing.assert_array_equal(
                    aa[k].sum(axis=0) if aa[k].ndim == 2 else aa[k],
                    ab[k].sum(axis=0) if ab[k].ndim == 2 else ab[k],
                    err_msg=f"via {n_via}: {k}")
            else:
                np.testing.assert_array_equal(aa[k], ab[k],
                                              err_msg=f"via {n_via}: {k}")
        assert queue_canon(aa) == queue_canon(ab), f"via {n_via}: queue order"
    # and the on-disk checkpoint is a valid recovery point
    engR = restore_engine(str(tmp_path / "soak"))
    _assert_conserved(engR, "restored from disk")
